"""Setup-time autotuner for the distributed ECG hot path.

The paper's thesis (§4.3) is that the right point-to-point strategy is
*predictable from a byte model*; this package extends that discipline to all
three t-dependent execution knobs of ``repro.sparse.spmbv``:

* exchange strategy in {standard, 2step, 3step, optimal} — Table-1 message
  statistics + the §4.3 max-rate models (``repro.core.models``);
* Block-ELL tile shape (br, bc) and the per-tile budget ``kmax`` — a
  zero-fill/alignment cost model over the matrix's block-structure histogram;
* blocking vs overlapped execution — the comm-hiding model
  ``max(T_interior, T_exchange) + T_boundary`` vs ``T_exchange + T_local``.

``tune(..., mode="model")`` evaluates the models only (pure host work, no
devices); ``mode="model:structural"`` swaps the exchange term for the
executor-structural model — each strategy's compiled plan charged
``dispatches × overhead + moved bytes`` — which ranks correctly on host/TPU
backends where the MPI max-rate terms do not apply; ``mode="measure"``
calibrates with setup-time microbenchmarks on a real mesh
(``repro.tune.microbench``).  All return a
:class:`~repro.tune.autotune.TunedConfig` that
``make_distributed_spmbv(..., tune=cfg)`` / ``distributed_ecg(..., tune=...)``
apply verbatim.  See ``docs/tuning.md`` for the model inputs and worked
examples.

The enlarging factor itself is tuned one level up:
:mod:`repro.adaptive.select_t` composes this package's per-iteration cost
model with an iterations-to-convergence model to rank candidate t at setup
(``t="auto"``); the chosen :class:`TSelection` is recorded on
``TunedConfig.selection``.  See ``docs/adaptive.md``.
"""

from repro.tune.autotune import (
    DEFAULT_TILES,
    TileStats,
    TunedConfig,
    method_sync_cost,
    predict_config,
    rank_methods,
    structural_exchange_cost,
    structural_exchange_costs,
    tile_stats,
    tile_time,
    tune,
    tunedconfig_from_dict,
    tunedconfig_to_dict,
)
from repro.tune.microbench import (
    measure_config,
    measure_dispatch_overhead,
    tune_measured,
)

__all__ = [
    "DEFAULT_TILES",
    "TileStats",
    "TunedConfig",
    "predict_config",
    "structural_exchange_cost",
    "structural_exchange_costs",
    "method_sync_cost",
    "rank_methods",
    "tile_stats",
    "tile_time",
    "tune",
    "tunedconfig_from_dict",
    "tunedconfig_to_dict",
    "measure_config",
    "measure_dispatch_overhead",
    "tune_measured",
]
