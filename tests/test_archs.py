"""Per-architecture smoke tests: reduced config, one real train step + one
decode step on CPU, asserting shapes and finiteness.  Full configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPE_CELLS, get_config, get_smoke, get_shapes
from repro.models.registry import model_api, serve_input_specs
from repro.models.common import MeshAxes
from repro.train import build_train_step, AdamWConfig, init_opt_state, DataConfig, batch_at


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


PUBLISHED_SIZES = {
    "phi3_medium_14b": 14.7e9,
    "stablelm_1_6b": 1.6e9,
    "granite_20b": 20e9,
    "granite_8b": 8e9,
    "mamba2_780m": 0.78e9,
    "whisper_medium": 0.77e9,
    "zamba2_1_2b": 1.2e9,
    "phi35_moe_42b": 42e9,
    "olmoe_1b_7b": 6.9e9,
    "paligemma_3b": 2.6e9,  # text backbone (vision tower stubbed)
}


class TestConfigs:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_param_count_matches_published(self, arch):
        cfg = get_config(arch)
        assert abs(cfg.param_count() - PUBLISHED_SIZES[arch]) / PUBLISHED_SIZES[arch] < 0.15

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_assigned_dims(self, arch):
        cfg = get_config(arch)
        # spot-check the assignment table
        table = {
            "phi3_medium_14b": (40, 5120, 40, 10, 17920, 100352),
            "stablelm_1_6b": (24, 2048, 32, 32, 5632, 100352),
            "granite_20b": (52, 6144, 48, 1, 24576, 49152),
            "granite_8b": (36, 4096, 32, 8, 14336, 49152),
            "mamba2_780m": (48, 1536, 0, 0, 0, 50280),
            "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
            "zamba2_1_2b": (38, 2048, 32, 32, 8192, 32000),
            "phi35_moe_42b": (32, 4096, 32, 8, 6400, 32064),
            "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
            "paligemma_3b": (18, 2048, 8, 1, 16384, 257216),
        }
        l, d, h, kv, f, v = table[arch]
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab) == (
            l, d, h, kv, f, v,
        )

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_shape_cells_defined(self, arch):
        shapes = get_shapes(arch)
        assert set(shapes) == set(SHAPE_CELLS)
        if arch in ("mamba2_780m", "zamba2_1_2b"):
            assert shapes["long_500k"] == "run"
        else:
            assert shapes["long_500k"].startswith("skip:")


class TestSmoke:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_train_step(self, mesh, arch):
        cfg = get_smoke(arch).with_(dtype=jnp.float32)
        api = model_api(cfg)
        bundle = build_train_step(
            cfg, mesh, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10), batch=2, seq=32
        )
        params = api.init_params(cfg, jax.random.key(0))
        before = [np.asarray(x) for x in jax.tree.leaves(params)]  # pre-donation copy
        opt = init_opt_state(params)
        dcfg = DataConfig(vocab=cfg.vocab, batch=2, seq=32)
        extra = {k: v for k, v in bundle.abstract_batch.items() if k not in ("tokens", "labels")}
        batch = batch_at(dcfg, 0, extra=extra)
        params2, opt2, metrics = bundle.step_fn(params, opt, batch)
        assert np.isfinite(float(metrics["loss"])), arch
        assert np.isfinite(float(metrics["grad_norm"])), arch
        assert float(metrics["grad_norm"]) > 0
        # params actually changed
        delta = max(
            float(np.abs(np.asarray(a, np.float32) - b).max())
            for a, b in zip(jax.tree.leaves(params2), before)
        )
        assert delta > 0, arch

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_loss_decreases(self, mesh, arch):
        cfg = get_smoke(arch).with_(dtype=jnp.float32)
        api = model_api(cfg)
        bundle = build_train_step(
            cfg, mesh, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30, weight_decay=0.0),
            batch=4, seq=32,
        )
        params = api.init_params(cfg, jax.random.key(0))
        opt = init_opt_state(params)
        dcfg = DataConfig(vocab=cfg.vocab, batch=4, seq=32)
        extra = {k: v for k, v in bundle.abstract_batch.items() if k not in ("tokens", "labels")}
        losses = []
        for step in range(12):
            batch = batch_at(dcfg, step, extra=extra)
            params, opt, metrics = bundle.step_fn(params, opt, batch)
            losses.append(float(metrics["loss"]))
        assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.05, (arch, losses)

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_decode_step(self, mesh, arch):
        cfg = get_smoke(arch).with_(dtype=jnp.float32)
        api = model_api(cfg)
        params = api.init_params(cfg, jax.random.key(0))
        cache = api.init_cache(cfg, 2, 16)
        step = jax.jit(api.decode_step(cfg, mesh))
        logits, cache2 = step(
            params, cache, {"token": jnp.array([1, 2], jnp.int32), "pos": jnp.zeros(2, jnp.int32)}
        )
        assert logits.shape == (2, cfg.vocab_padded), arch
        assert bool(jnp.isfinite(logits[:, : cfg.vocab]).all()), arch
        assert jax.tree.structure(cache2) == jax.tree.structure(cache)
