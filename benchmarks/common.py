"""Shared benchmark context: graphs, partitions, comm stats (built once)."""

from __future__ import annotations

import functools

import jax


def enable_x64():
    jax.config.update("jax_enable_x64", True)


@functools.lru_cache(maxsize=None)
def example_graph():
    from repro.sparse.matrices import example_2_1_graph

    return example_2_1_graph()  # full published scale (element level)


@functools.lru_cache(maxsize=None)
def suite_graph(name: str):
    from repro.sparse.matrices import surrogate_graph

    return surrogate_graph(name)


@functools.lru_cache(maxsize=None)
def comm_stats(which: str, p: int, ppn: int):
    from repro.sparse.partition import partition_csr
    from repro.core.comm_graph import build_comm_graph

    g, blk = example_graph() if which == "example" else suite_graph(which)
    pm = partition_csr(g, p)
    return build_comm_graph(pm, ppn=ppn, row_block=blk)


def timed(fn, *args, repeats: int = 3, label: str = "timed", **kw):
    """(result, wall microseconds per call) — median of repeats.

    A thin shim over :func:`repro.observe.timed_median` (one warmup call,
    ``block_until_ready`` inside the timed region); with a tracer installed
    via :func:`repro.observe.set_tracer` each timed call is a
    ``bench/<label>`` span.
    """
    from repro.observe import get_tracer, timed_median

    out, s = timed_median(fn, *args, repeats=repeats, label=label,
                          tracer=get_tracer(), **kw)
    return out, s * 1e6


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"
