"""The paper's §3.1 ECG iteration — two fused psums per iteration.

This is the historical ``make_ecg_runner`` loop body moved verbatim behind
the :class:`~repro.core.methods.base.MethodSpec` protocol: the op-for-op
identical closure structure keeps the refactored ``method="classic"`` solve
bit-identical to the pre-refactor engine (asserted by the handle-vs-legacy
equality checks in the test suite).

  per iteration —
    AZ   = A * Z                          SpMBV             (p2p comm)
    G    = ZᵀAZ                           gram1             (psum #1, t²)
    P    = Z C⁻¹ ;  AP = AZ C⁻¹           local chol + TRSMs
    [PᵀR | APᵀAP | AP_oldᵀAP]             gram2             (psum #2, 3t²)
    X   += P c ;  R -= AP c ;  Z = AP − P d − P_old d_old
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.adaptive.rankrev import rank_revealing_apply
from repro.adaptive.reduce import plateau_update, stagnation_mask
from repro.core.cg import EV_RECOVERY, EV_RESEED
from repro.core.methods.base import MethodContext, MethodSpec, _apply_vec, _chol_inv_apply


class ClassicMethod(MethodSpec):
    """Two-psum Grigori–Tissot ECG (Algorithms 1–3)."""

    name = "classic"

    def build(self, ctx: MethodContext):
        t = ctx.t
        max_iters = ctx.max_iters
        policy = ctx.policy
        use_mask = ctx.use_mask
        chol_eps = ctx.chol_eps
        a_apply = ctx.a_apply
        a_apply_masked = ctx.a_apply_masked
        split_fn = ctx.split_fn
        gram1, gram2, sqnorm, tail = ctx.gram1, ctx.gram2, ctx.sqnorm, ctx.tail
        precond, gram2p = ctx.precond, ctx.gram2p
        reseed = ctx.precond_reseed if precond is not None else None
        groups, sqnorm_cols = ctx.groups, ctx.sqnorm_cols
        # telemetry: record rank-revealing drops (EV_RECOVERY) and flexible
        # reseeds (EV_RESEED) per iteration whenever either mechanism runs
        track_events = policy is not None or reseed is not None

        def group_retire(big_r, z_new, active, k, carry):
            """Per-group convergence + retirement (packed multi-RHS solve).

            The per-column residual invariant of the splitting makes group
            j's true residual the sum of its own column slab; its norm rides
            ONE psum of g floats (``sqnorm_cols``) that *replaces* the scalar
            ``sqnorm`` collective — same collective count as a solo solve.

            Retirement has two independent halves, because R columns are
            group-owned but direction columns are not (the pivoted
            factorization reorders P/Z columns by pivot magnitude every
            iteration):

            * the retired group's **R slab** is zeroed — its c = PᵀR rows
              are zero from now on, so its X freezes at the retirement
              iterate (exact frozen-at-retirement semantics);
            * the **direction budget** shrinks to ``t′ · live_groups``: the
              trailing (smallest-pivot) active directions are dropped — the
              flexible-ECG width reduction, reusing the same zero-mask
              mechanics as the rank/stagnation drops — which is what lets
              the width-compacted exchange stop paying the retired bytes.
            """
            g_n, te = groups.n_groups, groups.t_each
            rsum_g = big_r.reshape(big_r.shape[0], g_n, te).sum(axis=2)
            grp_sq = sqnorm_cols(rsum_g)  # the iteration's ONE norm psum
            live_prev = carry["grp_live"]
            # retired groups carry their retirement-time norm forward
            grp_rn = jnp.where(live_prev, jnp.sqrt(grp_sq), carry["grp_rn"])
            tols = jnp.asarray(groups.tols, grp_rn.dtype)
            newly = live_prev & (grp_rn <= tols)
            grp_live = live_prev & ~newly
            grp_iter = jnp.where(newly, k + 1, carry["grp_iter"])
            live_cols = jnp.repeat(grp_live, te, total_repeat_length=g_n * te)
            # direction budget: keep the strongest t′·live pivot directions
            n_live_dirs = te * jnp.sum(grp_live).astype(jnp.int32)
            dir_act = active & (
                jnp.cumsum(active.astype(jnp.int32)) <= n_live_dirs
            )
            # stacked norm over groups: the guard/history scalar (breakdown
            # NaNs propagate through it; retired entries are frozen <= tol)
            rn = jnp.sqrt(jnp.sum(grp_rn * grp_rn))
            grp = dict(
                grp_rn=grp_rn, grp_live=grp_live, grp_iter=grp_iter,
                grp_hist=carry["grp_hist"].at[k + 1].set(grp_rn),
            )
            big_r = big_r * live_cols.astype(big_r.dtype)[None, :]
            z_new = z_new * dir_act.astype(z_new.dtype)[None, :]
            return big_r, z_new, dir_act, rn, grp

        def iterate(carry):
            big_x, big_r, z = carry["X"], carry["R"], carry["Z"]
            p_old, ap_old = carry["P"], carry["AP"]
            k, hist = carry["k"], carry["hist"]

            if use_mask:
                az = a_apply_masked(z, carry["act"])  # width-compacted SpMBV [p2p]
            else:
                az = a_apply(z)  # SpMBV  [p2p]
            g = gram1(z, az)  # allreduce #1: t² floats
            ev = jnp.int32(0)
            if policy is None:
                p, ap = _chol_inv_apply(g, z, az, eps=chol_eps)  # local chol + TRSMs
                active = None
            else:
                # pivoted rank-revealing factorization: dependent directions come
                # out as zero-masked columns instead of NaNs (local, no comm)
                (p, ap), _rank, active = rank_revealing_apply(
                    g, z, az, rtol=policy.rank_rtol
                )
                # fewer accepted pivots than live entering directions = a
                # rank drop the factorization just recovered from (the
                # entering width is last iteration's ahist entry)
                ev = ev | jnp.where(_rank < carry["ahist"][k], EV_RECOVERY, 0)

            # fused block inner products: one packed reduction of 3t² floats
            if precond is None:
                packed = gram2(p, big_r, ap, ap_old)  # allreduce #2: 3t² floats
            else:
                # flexible/preconditioned recurrence: the new directions are
                # built from W = M⁻¹AP, A-orthogonalized against P and P_old
                # — d = APᵀW, d_old = AP_oldᵀW ride the SAME single psum
                # (gram2p packs them with PᵀR), so preconditioning costs the
                # scheme no extra collective
                w = precond(ap, k)
                packed = gram2p(p, big_r, ap, ap_old, w)  # allreduce #2
            c, d, d_old = jnp.split(packed, 3, axis=1)

            # fused tail: X += Pc, R -= APc, Z = AP − Pd − P_old d_old
            big_x, big_r, z_new = tail(big_x, big_r, p, ap, p_old, c, d, d_old)
            if precond is not None:
                # Z = W − Pd − P_old d_old = tail's Z + (W − AP): reuse the
                # fused tail kernel unchanged, one extra (n, t) add
                z_new = z_new + (w - ap)
            if reseed is not None:
                # flexible restart: every ``reseed``-th iteration the chain
                # is reseeded from the preconditioned *updated* residual —
                # the only point where the residual re-enters the direction
                # sequence, which an iteration-varying M⁻¹ₖ requires (see
                # MethodContext.precond_reseed).  No extra collective: the
                # unorthogonalized seed goes through next iteration's Gram.
                do_rs = (k + 1) % reseed == 0
                z_new = jnp.where(do_rs, precond(big_r, k + 1), z_new)
                ev = ev | jnp.where(do_rs, EV_RESEED, 0)
            if policy is not None:
                # flexible-ECG stagnation drops; a zeroed Z column stays dead
                # (its G row/column is zero next iteration), so no mask needs
                # carrying for the maths — the block vectors themselves are the
                # mask.  The width-compacted exchange does carry it (``act``),
                # to know which columns to pack.
                active = stagnation_mask(c, carry["rn"], active, policy)
                z_new = z_new * active.astype(z_new.dtype)[None, :]
            if groups is None:
                rsum = big_r.sum(axis=1)
                rn = jnp.sqrt(sqnorm(rsum))
            else:
                big_r, z_new, active, rn, grp = group_retire(
                    big_r, z_new, active, k, carry
                )
            hist = hist.at[k + 1].set(rn)
            out = dict(
                X=big_x, R=big_r, Z=z_new, P=p, AP=ap, k=k + 1, rn=rn, hist=hist,
                bd=carry["bd"],
            )
            if groups is not None:
                out.update(grp)
            if track_events:
                out["evhist"] = carry["evhist"].at[k + 1].set(ev)
            if use_mask:
                out["act"] = active
            if policy is not None:
                n_active = jnp.sum(active).astype(jnp.int32)
                best_rn, since = plateau_update(
                    rn, carry["best_rn"], carry["since"], policy
                )
                restarts = carry["restarts"]
                if policy.restart:
                    # re-enlarge: rebuild the full t-wide splitting from the
                    # current residual when progress plateaus on a reduced block
                    do_rs = (since >= policy.plateau_window) & (n_active < t)
                    fresh = split_fn(rsum, t)
                    out["R"] = jnp.where(do_rs, fresh, out["R"])
                    out["Z"] = jnp.where(do_rs, fresh, out["Z"])
                    out["P"] = jnp.where(do_rs, jnp.zeros_like(p), out["P"])
                    out["AP"] = jnp.where(do_rs, jnp.zeros_like(ap), out["AP"])
                    n_active = jnp.where(do_rs, jnp.int32(t), n_active)
                    since = jnp.where(do_rs, 0, since)
                    best_rn = jnp.where(do_rs, rn, best_rn)
                    restarts = restarts + do_rs.astype(jnp.int32)
                out.update(
                    best_rn=best_rn, since=since, restarts=restarts,
                    ahist=carry["ahist"].at[k + 1].set(n_active),
                )
            return out

        def init(b, x0):
            n = b.shape[0]
            dtype = b.dtype
            zeros_nt = jnp.zeros((n, t), dtype)
            if groups is None:
                r0 = b - _apply_vec(a_apply, x0, t)  # initial SpMV (Alg 3 line 1)
                big_r0 = split_fn(r0, t)
                # preconditioned start: Z₀ = M⁻¹T(r₀); R stays the true residual
                z0 = big_r0 if precond is None else precond(big_r0, jnp.int32(0))
                rn0 = jnp.sqrt(sqnorm(r0))
                live_cols0 = None
            else:
                # packed start: b/x0 are (n, g); group j's initial guess rides
                # column j·t′ of one full-width SpMBV (one apply for all k
                # requests), and its residual is split at the per-group width
                # t′ into its own column slab
                g_n, te = groups.n_groups, groups.t_each
                offs = np.arange(g_n) * te
                x0w = jnp.zeros((n, t), dtype).at[:, offs].set(x0)
                r0 = b - a_apply(x0w)[:, offs]  # (n, g) per-request residuals
                big_r0 = jnp.concatenate(
                    [split_fn(r0[:, j], te) for j in range(g_n)], axis=1
                )
                grp_sq0 = sqnorm_cols(r0)
                grp_rn0 = jnp.sqrt(grp_sq0)
                tols = jnp.asarray(groups.tols, dtype)
                # a request already at its tolerance retires at iteration 0
                grp_live0 = grp_rn0 > tols
                live_cols0 = jnp.repeat(
                    grp_live0, te, total_repeat_length=t
                )
                colf = live_cols0.astype(dtype)[None, :]
                big_r0 = big_r0 * colf
                z0 = (
                    big_r0 if precond is None
                    else precond(big_r0, jnp.int32(0)) * colf
                )
                rn0 = jnp.sqrt(jnp.sum(grp_rn0 * grp_rn0))
            hist0 = jnp.full((max_iters + 1,), jnp.nan, dtype=dtype).at[0].set(rn0)
            carry = dict(X=zeros_nt, R=big_r0, Z=z0, P=zeros_nt, AP=zeros_nt,
                         k=jnp.int32(0), rn=rn0, hist=hist0,
                         bd=~jnp.isfinite(rn0))
            if groups is not None:
                carry.update(
                    grp_rn=grp_rn0,
                    grp_live=grp_live0,
                    grp_iter=jnp.where(grp_live0, jnp.int32(-1), jnp.int32(0)),
                    grp_hist=jnp.full(
                        (max_iters + 1, g_n), jnp.nan, dtype=dtype
                    ).at[0].set(grp_rn0),
                )
            if policy is not None:
                w0 = (
                    jnp.int32(t) if groups is None
                    else jnp.sum(live_cols0).astype(jnp.int32)
                )
                carry.update(
                    best_rn=rn0,
                    since=jnp.int32(0),
                    restarts=jnp.int32(0),
                    ahist=jnp.full((max_iters + 1,), -1, jnp.int32).at[0].set(w0),
                )
            if track_events:
                carry["evhist"] = (
                    jnp.full((max_iters + 1,), -1, jnp.int32).at[0].set(0)
                )
            if use_mask:
                carry["act"] = (
                    jnp.ones((t,), bool) if groups is None else live_cols0
                )
            return carry

        return init, iterate
